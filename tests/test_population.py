"""Population engine + device grid: property tests and gates.

Covers the PR-7 acceptance surface:

* move-operator algebra (search.mutate_vector / pair_swap / crossover):
  outputs are always valid multiplicity vectors, and pair swaps
  preserve the mean strong-pair density exactly (multiset invariance);
* vectorized candidate construction (batched._capped_rows /
  stack_multiplicity_candidates) is bit-equal to the per-plan path;
* the device grid engine (core/timing_jax.py) and the CandidateScorer
  on either backend are bit-exact against the numpy oracle;
* population_search provably matches-or-beats its embedded hill climb
  (containment) and is deterministic;
* diverse_frontier picks best-scored vectors with distinct densities.

Property tests run under the real `hypothesis` when installed and the
deterministic `_hyp_compat` fallback otherwise.
"""

import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.core import parsing, timing
from repro.core.delay import WORKLOADS
from repro.core.topology import ring_topology
from repro.design import batched, search
from repro.networks.zoo import get_network


def _overlay(net_name="gaia", wl_name="femnist"):
    net = get_network(net_name)
    wl = WORKLOADS[wl_name]
    return net, wl, ring_topology(net, wl).graph


def _random_vec(rng, n, t_max):
    return tuple(int(x) for x in rng.integers(1, t_max + 1, n))


# ---------------------------------------------------------------------------
# move operators
# ---------------------------------------------------------------------------


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=30),
       t_max=st.integers(min_value=1, max_value=8))
def test_mutate_vector_valid(seed, n, t_max):
    rng = np.random.default_rng(seed)
    vec = _random_vec(rng, n, t_max)
    out = search.mutate_vector(rng, vec, t_max)
    assert len(out) == n
    assert all(1 <= m <= t_max for m in out)
    if t_max == 1:
        assert out == vec            # no legal move at the walls
    else:
        diff = [i for i in range(n) if out[i] != vec[i]]
        assert len(diff) == 1
        assert abs(out[diff[0]] - vec[diff[0]]) == 1


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=30),
       t_max=st.integers(min_value=1, max_value=8))
def test_pair_swap_preserves_density(seed, n, t_max):
    rng = np.random.default_rng(seed)
    vec = _random_vec(rng, n, t_max)
    out = search.pair_swap(rng, vec)
    assert len(out) == n
    assert sorted(out) == sorted(vec)      # a permutation: same multiset
    # mean(1/m) is a multiset sum — permuting terms can only move the
    # pairwise summation order, never the value beyond ulp noise.
    assert search.strong_fraction(out) == pytest.approx(
        search.strong_fraction(vec), abs=1e-15)


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=30),
       t_max=st.integers(min_value=1, max_value=8))
def test_crossover_valid(seed, n, t_max):
    rng = np.random.default_rng(seed)
    a, b = _random_vec(rng, n, t_max), _random_vec(rng, n, t_max)
    out = search.crossover(rng, a, b)
    assert len(out) == n
    assert all(out[i] in (a[i], b[i]) for i in range(n))
    assert all(1 <= m <= t_max for m in out)


@settings(max_examples=20)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=2, max_value=30),
       t_max=st.integers(min_value=2, max_value=8))
def test_move_operator_registry_valid(seed, n, t_max):
    rng = np.random.default_rng(seed)
    a, b = _random_vec(rng, n, t_max), _random_vec(rng, n, t_max)
    for name, op in search.MOVE_OPERATORS.items():
        out = op(rng, a, b, t_max)
        assert len(out) == n, name
        assert all(isinstance(m, int) and 1 <= m <= t_max
                   for m in out), name


# ---------------------------------------------------------------------------
# vectorized candidate construction == per-plan oracle
# ---------------------------------------------------------------------------


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=12),
       t_max=st.integers(min_value=1, max_value=12),
       cap=st.integers(min_value=1, max_value=400))
def test_capped_rows_matches_dict_path(seed, n, t_max, cap):
    rng = np.random.default_rng(seed)
    mults = rng.integers(1, t_max + 1, (4, n))
    rows = batched._capped_rows(mults, cap)
    pairs = [(i, i + 1) for i in range(n)]
    for c in range(mults.shape[0]):
        ref = parsing.capped_multiplicities(
            dict(zip(pairs, (int(x) for x in mults[c]))), cap)
        assert [ref[p] for p in pairs] == rows[c].tolist()


def test_stacked_candidates_match_grid_arrays():
    net, wl, overlay = _overlay()
    rng = np.random.default_rng(3)
    cands = [_random_vec(rng, len(overlay.pairs), 5) for _ in range(12)]
    plans = [timing.multiplicity_vector_plan(net, wl, overlay, c)
             for c in cands]
    grid = timing.build_timing_grid(plans)
    comp = wl.compute_ms(net).astype(np.float64)
    batch = batched.stack_multiplicity_candidates(overlay, comp, cands)
    np.testing.assert_array_equal(batch.num_states, grid.num_states)
    np.testing.assert_array_equal(batch.strong, grid.strong)
    np.testing.assert_array_equal(batch.trans, grid.trans)
    np.testing.assert_array_equal(batch.lone_comp, grid.lone_comp)


def test_stacked_candidates_rejects_bad_input():
    net, wl, overlay = _overlay()
    comp = wl.compute_ms(net).astype(np.float64)
    with pytest.raises(ValueError, match="multiplicit"):
        batched.stack_multiplicity_candidates(
            overlay, comp, [(0,) * len(overlay.pairs)])


# ---------------------------------------------------------------------------
# device grid == host grid == per-cell oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("net_name", ["gaia", "geant"])
def test_jax_grid_bit_exact_paper_cells(net_name):
    from repro.core import timing_jax

    net, wl, overlay = _overlay(net_name)
    plans = [timing.multigraph_timing_plan(net, wl, t=t, overlay=overlay)
             for t in (2, 5)]
    grid = timing.build_timing_grid(plans)
    rounds = 900
    ref = grid.cycle_time_matrix(rounds)
    for bucket in (True, False):
        out = timing_jax.grid_recurrence_taus(
            grid.d0, grid.pair_comp, grid.strong, grid.trans,
            grid.lone_comp, grid.num_states, rounds, bucket=bucket)
        np.testing.assert_array_equal(out, ref)
    # Report-level equality (state statistics included), both backends.
    assert grid.reports(rounds, backend="jax") == \
        grid.reports(rounds, backend="numpy")


def test_grid_backend_unknown_raises():
    net, wl, overlay = _overlay()
    grid = timing.build_timing_grid(
        [timing.multigraph_timing_plan(net, wl, t=5, overlay=overlay)])
    with pytest.raises(ValueError, match="backend"):
        grid.reports(100, backend="torch")


def test_scorer_backends_bit_exact_vs_score_candidates():
    net, wl, overlay = _overlay()
    rng = np.random.default_rng(7)
    cands = [_random_vec(rng, len(overlay.pairs), 5) for _ in range(16)]
    rounds = 700
    ref = search.score_candidates(net, wl, overlay, cands, rounds)
    for backend in ("jax", "numpy"):
        fn = search.make_scorer(net, wl, overlay, rounds=rounds,
                                backend=backend)
        np.testing.assert_array_equal(fn(cands), ref)
        # Second call reuses the uploaded shared buffers (jax) / the
        # broadcast twins (numpy) — still exact.
        np.testing.assert_array_equal(fn(cands[:5]), ref[:5])


def test_scorer_empty_and_bad_backend():
    net, wl, overlay = _overlay()
    fn = search.make_scorer(net, wl, overlay, rounds=100)
    assert fn([]).shape == (0,)
    with pytest.raises(ValueError, match="backend"):
        batched.CandidateScorer(net, wl, overlay, rounds=100,
                                backend="torch")


# ---------------------------------------------------------------------------
# population engine gates
# ---------------------------------------------------------------------------


def test_population_matches_or_beats_hill_and_paper():
    net, wl, _ = _overlay()
    res, pool = search.population_search(net, wl, rounds=400, max_iters=4,
                                         pop_size=10, generations=3,
                                         seed=0)
    assert res.engine == "population" and res.backend == "jax"
    assert res.best_mean_ms <= res.hill_best_ms <= res.paper_mean_ms
    assert res.best_mean_ms == min(pool.values())
    assert pool[res.best_mults] == res.best_mean_ms
    # the density floor held throughout the evolution
    assert all(search.strong_fraction(v)
               >= res.paper_strong_frac - 1e-12 for v in pool)


def test_population_search_deterministic():
    net, wl, _ = _overlay()
    kw = dict(rounds=400, max_iters=3, pop_size=8, generations=3, seed=5)
    a, _ = search.population_search(net, wl, **kw)
    b, _ = search.population_search(net, wl, **kw)
    assert a.best_mults == b.best_mults
    assert a.best_mean_ms == b.best_mean_ms
    assert a.evaluations == b.evaluations


def test_population_backends_agree_on_best():
    net, wl, _ = _overlay()
    kw = dict(rounds=400, max_iters=3, pop_size=8, generations=2, seed=2)
    a, pa = search.population_search(net, wl, backend="jax", **kw)
    b, pb = search.population_search(net, wl, backend="numpy", **kw)
    # Bit-identical scoring => identical trajectories, pools and winner.
    assert a.best_mults == b.best_mults
    assert a.best_mean_ms == b.best_mean_ms
    assert pa == pb


def test_diverse_frontier_distinct_densities():
    pool = {
        (1, 1): 10.0,   # density 1.0
        (1, 2): 8.0,    # density 0.75
        (2, 1): 9.0,    # density 0.75 (clone of the better one)
        (2, 2): 7.0,    # density 0.5
        (2, 3): 6.5,    # paper — always excluded
    }
    paper = (2, 3)
    picks = search.diverse_frontier(pool, paper, 3)
    assert paper not in picks
    # Best score first, then best at each still-unseen density — the
    # 0.75-density clone (2, 1) loses to the worse-scored (1, 1).
    assert picks == [(2, 2), (1, 2), (1, 1)]
    # K=2 keeps only the distinct-density head.
    assert search.diverse_frontier(pool, paper, 2) == [(2, 2), (1, 2)]
    # Once densities are exhausted the remainder fills by score.
    assert search.diverse_frontier(pool, paper, 4) == [
        (2, 2), (1, 2), (1, 1), (2, 1)]


def test_search_cli_population_smoke(capsys):
    rc = search.main(["--networks", "gaia", "--workloads", "femnist",
                      "--rounds", "300", "--max-iters", "2",
                      "--engine", "population", "--backend", "jax",
                      "--pop-size", "6", "--generations", "2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "population" in out and "gaia" in out
    assert "matched or beat" in out
