"""Tiny fallback for `hypothesis` so property tests run everywhere.

The container this repo targets does not ship hypothesis; the test
modules use only a small slice of its API (`given`, `settings`,
`strategies.integers`). When the real library is importable we re-export
it untouched; otherwise `given` expands into a deterministic sample of
examples drawn from each strategy's range (seeded, so failures
reproduce), and `settings` honours `max_examples` as the sample size.

Usage in test modules:

    from _hyp_compat import given, settings, st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    import dataclasses
    import functools
    import inspect
    import itertools

    import numpy as np

    @dataclasses.dataclass(frozen=True)
    class _IntRange:
        lo: int
        hi: int  # inclusive, mirroring st.integers

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntRange:
            return _IntRange(int(min_value), int(max_value))

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            inner = fn

            @functools.wraps(inner)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", 20)
                rng = np.random.default_rng(0)
                names = sorted(strategies)
                # Always include the range corners for the first argument
                # (cheap edge-case coverage), then random draws.
                draws = []
                first = strategies[names[0]]
                for corner in {first.lo, first.hi}:
                    ex = {names[0]: corner}
                    for nm in names[1:]:
                        s = strategies[nm]
                        ex[nm] = int(rng.integers(s.lo, s.hi + 1))
                    draws.append(ex)
                for _ in range(max(n - len(draws), 0)):
                    draws.append({nm: int(rng.integers(strategies[nm].lo,
                                                       strategies[nm].hi + 1))
                                  for nm in names})
                for ex in itertools.islice(draws, n):
                    inner(*args, **kwargs, **ex)

            # settings() may be applied above or below @given; forward the
            # attribute either way.
            if hasattr(inner, "_hyp_max_examples"):
                wrapper._hyp_max_examples = inner._hyp_max_examples
            # All strategy parameters are supplied here — hide them from
            # pytest's fixture resolution (hypothesis does the same).
            wrapper.__signature__ = inspect.Signature()
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper

        return deco
