"""Unit + property tests for repro.core graph algorithms (the paper's

Algorithm 1 / Algorithm 2 and their invariants)."""

import math

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # hypothesis or local fallback

from repro.core import parsing
from repro.core.delay import FEMNIST, Workload, graph_pair_delays
from repro.core.graph import (STRONG, WEAK, MultigraphState, canon,
                              make_graph)
from repro.core.multigraph import build_multigraph
from repro.core.topology import ring_topology
from repro.networks.zoo import NetworkSpec, Silo, get_network

# ---------------------------------------------------------------------------
# helpers: random small networks for property tests
# ---------------------------------------------------------------------------


def _random_network(seed: int, n: int) -> NetworkSpec:
    rng = np.random.default_rng(seed)
    lats = rng.uniform(-60, 60, n)
    lons = rng.uniform(-180, 180, n)
    silos = tuple(
        Silo(name=f"s{i}", lat=float(lats[i]), lon=float(lons[i]),
             upload_gbps=float(rng.uniform(1, 10)),
             download_gbps=float(rng.uniform(1, 10)),
             compute_scale=float(rng.uniform(0.8, 1.2)))
        for i in range(n))
    # latency from coordinates via the zoo's own model
    from repro.networks.zoo import _latency_matrix
    lat = _latency_matrix([(s.name, s.lat, s.lon) for s in silos])
    return NetworkSpec(name=f"rand{seed}", silos=silos, latency_ms=lat)


# ---------------------------------------------------------------------------
# graph basics
# ---------------------------------------------------------------------------


def test_canon_and_dedup():
    g = make_graph(4, [(1, 0), (0, 1), (2, 3)])
    assert g.pairs == ((0, 1), (2, 3))
    assert list(g.degrees()) == [1, 1, 1, 1]


def test_self_pair_rejected():
    with pytest.raises(ValueError):
        canon(2, 2)


def test_connectivity_check():
    assert make_graph(3, [(0, 1), (1, 2)]).is_connected()
    assert not make_graph(4, [(0, 1), (2, 3)]).is_connected()


def test_isolated_nodes_definition():
    st_ = MultigraphState(num_nodes=4, edge_type={
        (0, 1): STRONG, (1, 2): WEAK, (2, 3): WEAK})
    # 2 and 3 touch only weak edges -> isolated; 0,1 touch a strong edge.
    assert st_.isolated_nodes() == (2, 3)
    assert st_.has_isolated()


# ---------------------------------------------------------------------------
# Algorithm 1 invariants
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**16), n=st.integers(4, 12), t=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_algorithm1_multiplicities(seed, n, t):
    net = _random_network(seed, n)
    overlay = ring_topology(net, FEMNIST).graph
    mg = build_multigraph(net, FEMNIST, overlay, t=t)
    # Every overlay pair appears; multiplicity within [1, t].
    assert set(mg.multiplicity) == set(overlay.pairs)
    for p, m in mg.multiplicity.items():
        assert 1 <= m <= t
    # The min-delay pair always has multiplicity 1 (d/d_min rounds to 1).
    delays = graph_pair_delays(net, FEMNIST, overlay)
    pmin = min(delays, key=delays.get)
    assert mg.multiplicity[pmin] == 1
    # Monotone: larger delay never gets fewer edges.
    ds = sorted(delays.items(), key=lambda kv: kv[1])
    ms = [mg.multiplicity[p] for p, _ in ds]
    assert all(a <= b for a, b in zip(ms, ms[1:]))


def test_algorithm1_t1_is_overlay():
    net = get_network("gaia")
    overlay = ring_topology(net, FEMNIST).graph
    mg = build_multigraph(net, FEMNIST, overlay, t=1)
    assert all(m == 1 for m in mg.multiplicity.values())
    states = parsing.parse_multigraph(mg)
    # t=1 -> single state == overlay, no weak edges, no isolated nodes
    # (paper Table 6: t=1 reduces to RING's overlay).
    assert len(states) == 1
    assert states[0].weak_pairs() == ()
    assert not states[0].has_isolated()


# ---------------------------------------------------------------------------
# Algorithm 2 invariants
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**16), n=st.integers(4, 10), t=st.integers(2, 6))
@settings(max_examples=25, deadline=None)
def test_algorithm2_parse_invariants(seed, n, t):
    net = _random_network(seed, n)
    overlay = ring_topology(net, FEMNIST).graph
    mg = build_multigraph(net, FEMNIST, overlay, t=t)
    s_max = parsing.max_states(mg)
    lcm = 1
    for m in mg.multiplicity.values():
        lcm = math.lcm(lcm, m)
    assert s_max == lcm

    states = parsing.parse_multigraph(mg)
    assert len(states) == s_max
    # State 0 is the overlay: every pair strong (paper: "The first state
    # is always the overlay").
    assert states[0].strong_pairs() == tuple(sorted(mg.multiplicity))
    # Every state covers every pair exactly once (simple graph states).
    for s in states:
        assert set(s.edge_type) == set(mg.multiplicity)
    # Pair with multiplicity m is strong exactly every m-th state.
    for p, m in mg.multiplicity.items():
        pattern = [s.edge_type[p] for s in states]
        for k, e in enumerate(pattern):
            assert e == (STRONG if k % m == 0 else WEAK)
    # Across one full cycle each pair is strong exactly s_max/m times.
    for p, m in mg.multiplicity.items():
        strong_count = sum(s.edge_type[p] == STRONG for s in states)
        assert strong_count == s_max // m


def test_parse_cap_states():
    net = get_network("gaia")
    overlay = ring_topology(net, FEMNIST).graph
    mg = build_multigraph(net, FEMNIST, overlay, t=5)
    states = parsing.parse_multigraph(mg, cap_states=7)
    assert len(states) <= 7


def test_state_schedule_cycles():
    net = get_network("gaia")
    overlay = ring_topology(net, FEMNIST).graph
    mg = build_multigraph(net, FEMNIST, overlay, t=3)
    states = parsing.parse_multigraph(mg)
    seq = list(parsing.state_schedule(states, 2 * len(states) + 3))
    assert seq[0][1] is states[0]
    assert seq[len(states)][1] is states[0]
    assert seq[len(states) + 1][1] is states[1]
