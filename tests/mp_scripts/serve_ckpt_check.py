"""FL-checkpoint round-trip at D=8: run in a SUBPROCESS with 8 forced
host devices (tests/test_serving_loop.py drives this; the main pytest
process must keep seeing 1 device). Trains the reduced-LM FL loop on
the mesh runtime sharded over 8 devices and emits a checkpoint; the
parent compares it bit-for-bit against its own single-device run —
the gather-before-save contract of checkpoint/ckpt.py."""

import os
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.launch.train import TrainConfig, run_reduced_fl  # noqa: E402

assert jax.device_count() == 8, jax.device_count()

out = run_reduced_fl(TrainConfig(
    arch="mamba2-370m", network="gaia", silos=6, rounds=2, t=2,
    seq_len=16, batch_size=2, mesh="auto",
    ckpt_dir=sys.argv[1], ckpt_every=0))
print("d8-ckpt-steps:", out["ckpt_steps"])
print("d8-mesh-ckpt-ok")
