"""Mesh-sharded FL runtime checks at D=8. Run in a SUBPROCESS with
xla_force_host_platform_device_count=8 (tests/test_fl_mesh.py drives
this); the main pytest process must keep seeing 1 device. The same
assertions also run in-process in the fl-mesh CI job, where the whole
pytest process is launched with 8 forced host devices."""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.delay import FEMNIST  # noqa: E402
from repro.fl import dpasgd, mesh as flmesh, runtime as rtmod  # noqa: E402
from repro.networks.zoo import get_network  # noqa: E402
from repro.optim import flat_sgd  # noqa: E402

D_MODEL = 8


def _toy_init(key):
    return {"w": jax.random.normal(key, (D_MODEL,)), "b": jnp.zeros((3,))}


def _toy_loss(p, batch):
    return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)


def _run_single(plan, key, batches_all, momentum):
    n = int(plan.diag.shape[1])
    opt = flat_sgd(0.05, momentum=momentum)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(_toy_init, key), n)
    state = rtmod.init_flat_state(_toy_init, opt, rt, key)
    cycle = rtmod.make_cycle_fn(rt, loss_fn=_toy_loss, opt=opt)
    r = batches_all.shape[0]
    state, losses = cycle(state, {"t": jnp.asarray(batches_all)},
                          jnp.asarray(rt.strong[:r]),
                          jnp.asarray(rt.coeffs[:r]),
                          jnp.asarray(rt.diag[:r]))
    return rt, state, np.asarray(losses)


def _run_mesh(plan, key, batches_all, momentum, backend):
    n = int(plan.diag.shape[1])
    opt = flat_sgd(0.05, momentum=momentum)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(_toy_init, key), n)
    mrt = flmesh.make_mesh_runtime(rt)
    state = flmesh.init_mesh_state(_toy_init, opt, mrt, key)
    cycle = rtmod.make_cycle_fn(mrt, loss_fn=_toy_loss, opt=opt,
                                gossip=backend)
    r = batches_all.shape[0]
    state, losses = cycle(state, {"t": jnp.asarray(batches_all)},
                          jnp.asarray(rt.strong[:r]),
                          jnp.asarray(rt.coeffs[:r]),
                          jnp.asarray(rt.diag[:r]))
    return mrt, state, np.asarray(losses), cycle


def main():
    assert jax.device_count() == 8, jax.device_count()

    for net_name in ("gaia", "amazon"):
        net = get_network(net_name)
        plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
        r, n = plan.num_rounds_cycle, net.num_silos
        rng = np.random.default_rng(0)
        batches = np.asarray(rng.normal(size=(r, 2, n, 1, D_MODEL)),
                             np.float32)
        key = jax.random.PRNGKey(7)
        rt, s1, l1 = _run_single(plan, key, batches, momentum=0.9)
        for backend in ("halo", "all_gather"):
            mrt, sm, lm, _ = _run_mesh(plan, key, batches, 0.9, backend)
            flat = flmesh.gather_flat_state(mrt, sm)
            np.testing.assert_array_equal(np.asarray(s1.w),
                                          np.asarray(flat.w))
            np.testing.assert_array_equal(np.asarray(s1.buffers),
                                          np.asarray(flat.buffers))
            np.testing.assert_array_equal(
                np.asarray(s1.opt_state["mu"]),
                np.asarray(flat.opt_state["mu"]))
            # reported loss scalars: ~1 ulp reduce-emitter tolerance
            # (the training state above is exact; DESIGN.md §16)
            np.testing.assert_allclose(l1, lm, rtol=5e-7, atol=0)
            print(f"{net_name}-{backend}-bitexact-ok")

    # live-swap contract: two different schedules over the SAME CSR
    # structure run through ONE trace of the mesh cycle
    net = get_network("gaia")
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    r, n = plan.num_rounds_cycle, net.num_silos
    rng = np.random.default_rng(1)
    batches = np.asarray(rng.normal(size=(r, 1, n, 1, D_MODEL)), np.float32)
    key = jax.random.PRNGKey(9)
    mrt, state, _, cycle = _run_mesh(plan, key, batches, 0.9, "halo")
    swapped = ~np.asarray(mrt.strong)  # arbitrary same-shape schedule
    state, losses = cycle(state, {"t": jnp.asarray(batches)},
                          jnp.asarray(swapped),
                          jnp.asarray(mrt.coeffs),
                          jnp.asarray(mrt.diag))
    assert losses.shape == (r,)
    assert cycle.trace_count["count"] == 1, cycle.trace_count
    print("swap-trace-once-ok")


if __name__ == "__main__":
    main()
