"""Multi-device gossip backend checks. Run in a SUBPROCESS with

xla_force_host_platform_device_count=8 (tests/test_fl.py drives this);
the main pytest process must keep seeing 1 device."""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
try:  # jax >= 0.5 exports it at top level
    from jax import shard_map  # noqa: E402
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map  # noqa: E402

from repro.core.consensus import metropolis_weights  # noqa: E402
from repro.core.graph import make_graph  # noqa: E402
from repro.fl.gossip import (gossip_dense, gossip_ring_ppermute,  # noqa: E402
                             init_ring_buffers, ring_coefficients)


def main():
    n = 8
    assert jax.device_count() == n, jax.device_count()
    mesh = jax.make_mesh((n,), ("silo",))

    ring = make_graph(n, [(i, (i + 1) % n) for i in range(n)])
    a = jnp.asarray(metropolis_weights(ring), jnp.float32)

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(n, 16, 32)), jnp.float32)
    params = {"w": w}  # leading silo axis, sharded over the mesh

    # ---- dense backend == matrix product ----
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=({"w": P("silo")}, None), out_specs={"w": P("silo")})
    def dense_step(p, amat):
        local = {"w": p["w"][0]}  # shed the silo axis inside the shard
        out = gossip_dense(local, amat, "silo")
        return {"w": out["w"][None]}

    got = dense_step(params, a)["w"]
    want = jnp.einsum("ij,jkl->ikl", a, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("dense-ok")

    # ---- ring ppermute backend: strong round == dense with ring MH ----
    cs, cl, cr = ring_coefficients(n)

    def ring_step(p, bufs, active_left, active_right):
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=({"w": P("silo")},
                      {"left": {"w": P("silo")}, "right": {"w": P("silo")}},
                      None, None, None),
            out_specs=({"w": P("silo")},
                       {"left": {"w": P("silo")}, "right": {"w": P("silo")}}))
        def inner(p, bufs, cs_, cl_, cr_):
            local = {"w": p["w"][0]}
            lb = {"w": bufs["left"]["w"][0]}
            rb = {"w": bufs["right"]["w"][0]}
            out, nb = gossip_ring_ppermute(
                local, {"left": lb, "right": rb},
                coeff_self=cs_, coeff_left=cl_, coeff_right=cr_,
                axis="silo", active_left=active_left,
                active_right=active_right)
            return ({"w": out["w"][None]},
                    {"left": {"w": nb["left"]["w"][None]},
                     "right": {"w": nb["right"]["w"][None]}})

        return inner(p, bufs, cs, cl, cr)

    bufs = {"left": {"w": w.copy()}, "right": {"w": w.copy()}}
    got, nb = ring_step(params, bufs, True, True)
    want = jnp.einsum("ij,jkl->ikl", a, w)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("ring-strong-ok")

    # buffers now hold the true neighbours
    np.testing.assert_allclose(np.asarray(nb["left"]["w"]),
                               np.asarray(jnp.roll(w, 1, axis=0)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nb["right"]["w"]),
                               np.asarray(jnp.roll(w, -1, axis=0)),
                               rtol=1e-6, atol=1e-6)
    print("ring-buffers-ok")

    # ---- weak round: NO collective; uses stale buffers ----
    w2 = jnp.asarray(rng.normal(size=(n, 16, 32)), jnp.float32)
    got2, _ = ring_step({"w": w2}, nb, False, False)
    want2 = (cs[:, None, None] * w2 +
             cl[:, None, None] * jnp.roll(w, 1, axis=0) +
             cr[:, None, None] * jnp.roll(w, -1, axis=0))
    np.testing.assert_allclose(np.asarray(got2["w"]), np.asarray(want2),
                               rtol=1e-5, atol=1e-6)
    print("ring-weak-ok")

    # ---- use_kernel: flat-packed fused combine == jnp path ----
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=({"w": P("silo")},
                  {"left": {"w": P("silo")}, "right": {"w": P("silo")}},
                  None, None, None),
        out_specs={"w": P("silo")},
        check_rep=False)  # pallas_call has no replication rule
    def ring_step_kernel(p, bufs, cs_, cl_, cr_):
        local = {"w": p["w"][0]}
        lb = {"w": bufs["left"]["w"][0]}
        rb = {"w": bufs["right"]["w"][0]}
        out, _ = gossip_ring_ppermute(
            local, {"left": lb, "right": rb},
            coeff_self=cs_, coeff_left=cl_, coeff_right=cr_,
            axis="silo", active_left=True, active_right=True,
            use_kernel=True)
        return {"w": out["w"][None]}

    got_k = ring_step_kernel(params, bufs, cs, cl, cr)["w"]
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("ring-kernel-ok")

    # ---- HLO check: weak round must not contain collective-permute ----
    import jax._src.test_util as _  # noqa: F401

    def lower_txt(active):
        fn = jax.jit(lambda p, b: ring_step(p, b, active, active))
        return fn.lower(params, bufs).as_text()

    strong_txt = lower_txt(True)
    weak_txt = lower_txt(False)
    names = ("collective-permute", "collective_permute", "ppermute")
    assert any(nm in strong_txt for nm in names), "no permute in strong HLO"
    assert not any(nm in weak_txt for nm in names), "permute leaked into weak HLO"
    print("hlo-ok")


if __name__ == "__main__":
    main()
