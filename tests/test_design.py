"""Topology-design subsystem tests (repro/design/):

  * catalog families == the `timing.make_timing_plan` dispatch they
    now implement, and `core.topology` shim identity;
  * batched Christofides tours / min-weight matchings == the per-item
    networkx oracles on random metric graphs (dedup is exact-bytes);
  * factorized MATCHA sampler == `timing.sampled_cycle_times`
    bit-for-bit on complete (odd and even N) bases, and the
    non-factorized fallback is the general engine itself;
  * shared sweep construction (`SweepConstructor` / DesignContext) ==
    legacy per-cell construction, report-for-report and
    cycle-times-exact, with lazy == eager sampled plans;
  * grid retirement: `TimingGrid` with per-cell retirement == the
    non-retiring path == the per-cell oracles, bit-for-bit;
  * multiplicity search: the Algorithm-1 vector routed through
    `multiplicity_plan` == `multigraph_timing_plan`, the search
    matches or beats the paper design under the density floor, and the
    CLI exits 0.
"""

import numpy as np
import pytest

from _hyp_compat import given, settings, st  # hypothesis or local fallback
from repro.core import timing
from repro.core.delay import FEMNIST, WORKLOADS
from repro.core.graph import make_graph
from repro.core.multigraph import build_multigraph
from repro.design import batched, catalog, search
from repro.networks.zoo import NetworkSpec, Silo, get_network

GAIA = get_network("gaia")


def _tiny_net(n, latency=5.0, hetero=True, name=None):
    silos = tuple(
        Silo(name=f"s{i}", lat=float(i), lon=0.0,
             upload_gbps=10.0 * (1.0 + 0.1 * i if hetero else 1.0),
             download_gbps=10.0 * (1.0 + 0.07 * i if hetero else 1.0),
             compute_scale=1.0 + (0.05 * i if hetero else 0.0))
        for i in range(n))
    rng = np.random.default_rng(n)
    lat = rng.uniform(1.0, latency, (n, n))
    lat = np.maximum(lat, lat.T)
    np.fill_diagonal(lat, 0.0)
    return NetworkSpec(name=name or f"tiny{n}", silos=silos, latency_ms=lat)


def _metric_matrix(rng, n):
    """Random symmetric metric-ish weight matrix (positive, zero diag)."""
    pts = rng.uniform(0.0, 100.0, (n, 2))
    d = np.hypot(pts[:, 0][:, None] - pts[:, 0][None, :],
                 pts[:, 1][:, None] - pts[:, 1][None, :])
    np.fill_diagonal(d, 0.0)
    return d


# ---------------------------------------------------------------------------
# catalog families own construction + timing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", ["star", "matcha", "matcha_plus", "mst",
                                  "dmbst", "ring", "multigraph"])
def test_family_timing_plan_matches_make_timing_plan(topo):
    fam = catalog.get_family(topo, sample_rounds=64)
    plan = fam.timing_plan(GAIA, FEMNIST)
    ref = timing.make_timing_plan(topo, GAIA, FEMNIST, sample_rounds=64)
    assert plan.report(64) == ref.report(64)
    np.testing.assert_array_equal(plan.cycle_times(64),
                                  ref.cycle_times(64))


def test_family_build_matches_legacy_builders():
    assert (catalog.get_family("ring").build(GAIA, FEMNIST).graph
            == catalog.ring_topology(GAIA, FEMNIST).graph)
    assert (catalog.get_family("mst").build(GAIA, FEMNIST).graph
            == catalog.mst_topology(GAIA, FEMNIST).graph)
    mg = catalog.get_family("multigraph", t=3).build(GAIA, FEMNIST)
    ref = build_multigraph(GAIA, FEMNIST,
                           catalog.ring_topology(GAIA, FEMNIST).graph, t=3)
    assert mg.multiplicity == ref.multiplicity


def test_core_topology_shim_reexports_catalog():
    """`core.topology` is a pure re-export: same objects, not copies."""
    from repro.core import topology

    assert topology.ring_topology is catalog.ring_topology
    assert topology.MatchaTopology is catalog.MatchaTopology
    assert topology.build_topology is catalog.build_topology
    assert topology.TOPOLOGIES is catalog.TOPOLOGIES


# ---------------------------------------------------------------------------
# batched graph algorithms == per-item networkx oracles
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_batched_christofides_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    mats = [_metric_matrix(rng, int(rng.integers(4, 12)))
            for _ in range(int(rng.integers(2, 5)))]
    mats.append(mats[0].copy())     # exercise the dedup path
    tours = batched.christofides_tours(mats)
    for d, tour in zip(mats, tours):
        assert tour == catalog.christofides_cycle(d)
    assert tours[-1] == tours[0]


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_batched_min_weight_matchings_match_oracle(seed):
    import networkx as nx

    rng = np.random.default_rng(seed)
    mats, nodesets = [], []
    for _ in range(int(rng.integers(2, 5))):
        n = int(rng.integers(4, 12))
        d = _metric_matrix(rng, n)
        k = 2 * int(rng.integers(1, n // 2 + 1))   # even subset size
        mats.append(d)
        nodesets.append(sorted(rng.choice(n, size=k, replace=False)))
    mats.append(mats[0].copy())
    nodesets.append(list(nodesets[0]))
    got = batched.min_weight_matchings(mats, nodesets)
    for d, nodes, m in zip(mats, nodesets, got):
        g = nx.Graph()
        for x, i in enumerate(nodes):
            for j in nodes[x + 1:]:
                g.add_edge(int(i), int(j), weight=float(d[i, j]))
        ref = {tuple(sorted(p)) for p in nx.min_weight_matching(g)}
        assert m == ref


# ---------------------------------------------------------------------------
# factorized MATCHA sampler == the general engine, bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 5, 6, 7])   # even and odd complete bases
def test_factorized_sampler_matches_oracle_tiny(n):
    net = _tiny_net(n, hetero=True)
    design = catalog.matcha_topology(net, FEMNIST, seed=3)
    assert batched._detect_factorization(design.matchings, n) is not None
    rounds = 300
    ref = timing.sampled_cycle_times(design, net, FEMNIST, rounds)
    got = batched.batched_sampled_cycle_times(design, net, FEMNIST, rounds)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("netname,topo", [
    ("gaia", "matcha"),          # odd complete (11)
    ("amazon", "matcha"),        # even complete (22)
    ("geant", "matcha_plus"),    # physical base -> general fallback
])
def test_factorized_sampler_matches_oracle_paper(netname, topo):
    net = get_network(netname)
    design = catalog.build_topology(topo, net, FEMNIST, seed=0)
    rounds = 400
    ref = timing.sampled_cycle_times(design, net, FEMNIST, rounds)
    got = batched.batched_sampled_cycle_times(design, net, FEMNIST, rounds)
    np.testing.assert_array_equal(got, ref)


def test_lazy_sampled_plan_equals_eager():
    design = catalog.matcha_topology(GAIA, FEMNIST, seed=0)
    lazy = timing.sampled_timing_plan("matcha", GAIA, FEMNIST, design,
                                      sample_rounds=200)
    assert lazy.period_times is None          # nothing materialized yet
    eager = timing.sampled_cycle_times(design, GAIA, FEMNIST, 200)
    np.testing.assert_array_equal(lazy.cycle_times(200), eager)
    assert lazy.report(200).total_time_s == float(eager.sum()) / 1e3


# ---------------------------------------------------------------------------
# shared sweep construction == legacy per-cell construction
# ---------------------------------------------------------------------------


def test_shared_construction_bitexact_vs_legacy():
    """The whole shared-construction surface on a grid that exercises
    every artifact: nominal-matrix reuse (mst+dmbst+ring), ring-graph
    reuse (ring+multigraph t=3,5), per-network decompositions, the
    factorized sampler (complete base) and the matcha+ fallback, and
    MATCHA==MATCHA+ horizon dedup on a fully-meshed cloud network."""
    from repro.core import sweep

    cfg = sweep.SweepConfig(
        topologies=("star", "matcha", "matcha_plus", "mst", "dmbst",
                    "ring", "multigraph"),
        networks=("gaia", "geant"), workloads=("femnist", "sentiment140"),
        t_values=(3, 5), num_rounds=500)
    shared_plans, _ = sweep.build_sweep_plans(cfg, shared=True)
    legacy_plans, _ = sweep.build_sweep_plans(cfg, shared=False)
    assert len(shared_plans) == len(legacy_plans)
    for s, l in zip(shared_plans, legacy_plans):
        np.testing.assert_array_equal(
            s.cycle_times(cfg.num_rounds), l.cycle_times(cfg.num_rounds),
            err_msg=f"{l.topology}/{l.network}/{l.workload}")
        assert s.report(cfg.num_rounds) == l.report(cfg.num_rounds)
    # and the full run_sweep paths agree cell-for-cell
    a = sweep.run_sweep(cfg, batched=True, shared=True)
    b = sweep.run_sweep(cfg, batched=False, shared=False)
    for ca, cb in zip(a, b):
        assert ca.report == cb.report


def test_matcha_plus_horizon_dedup_on_cloud_networks():
    """On fully-meshed gaia, MATCHA and MATCHA(+) are the same design:
    the context must hand both the identical horizon object."""
    ctx = batched.DesignContext(GAIA)
    m = catalog.get_family("matcha", sample_rounds=100)
    p = catalog.get_family("matcha_plus", sample_rounds=100)
    t1 = m.timing_plan(GAIA, FEMNIST, ctx=ctx).cycle_times(100)
    t2 = p.timing_plan(GAIA, FEMNIST, ctx=ctx).cycle_times(100)
    np.testing.assert_array_equal(t1, t2)
    assert len(ctx._sampled) == 1        # one cached horizon, not two


# ---------------------------------------------------------------------------
# grid retirement == non-retiring == per-cell, bit-for-bit
# ---------------------------------------------------------------------------


def _grid_all_paths_equal(plans, rounds):
    grid = timing.build_timing_grid(plans)
    retired = grid.cycle_time_matrix(rounds, retire=True)
    full = grid.cycle_time_matrix(rounds, retire=False)
    np.testing.assert_array_equal(retired, full)
    for c, plan in enumerate(plans):
        np.testing.assert_array_equal(
            retired[c], plan.cycle_times(rounds),
            err_msg=f"cell {c}: {plan.topology}/{plan.network}")
    for ra, rb in zip(grid.reports(rounds, retire=True),
                      grid.reports(rounds, retire=False)):
        assert ra == rb


def test_grid_retirement_bitexact_paper_cells():
    """Mixed transient lengths: small gaia cells lock their orbits long
    before the larger geant cells, so rows genuinely retire early and
    the tails are tiled from each cell's own lock round."""
    plans = [timing.multigraph_timing_plan(get_network(n), WORKLOADS[w],
                                           t=t)
             for n in ("gaia", "geant")
             for w in ("femnist", "inaturalist")
             for t in (3, 5)]
    _grid_all_paths_equal(plans, 900)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_grid_retirement_bitexact_random_cells(seed):
    rng = np.random.default_rng(seed)
    plans = []
    for _ in range(int(rng.integers(2, 5))):
        n = int(rng.integers(3, 9))
        net = _tiny_net(n, latency=float(rng.uniform(2.0, 30.0)),
                        hetero=bool(rng.integers(0, 2)))
        pairs = {(i, (i + 1) % n) if i < (i + 1) % n else ((i + 1) % n, i)
                 for i in range(n)}
        extra = [(i, j) for i in range(n) for j in range(i + 1, n)
                 if rng.random() < 0.3]
        overlay = make_graph(n, list(pairs) + extra)
        plans.append(timing.multigraph_timing_plan(
            net, FEMNIST, t=int(rng.integers(2, 7)), overlay=overlay))
    _grid_all_paths_equal(plans, int(rng.integers(50, 400)))


# ---------------------------------------------------------------------------
# multiplicity search
# ---------------------------------------------------------------------------


def test_multiplicity_plan_matches_multigraph_plan():
    """Algorithm 1's vector through the search constructor must be the
    SAME plan the paper pipeline builds (same Eq. 4 arrays, same
    schedule, same cycle times)."""
    overlay = catalog.ring_topology(GAIA, FEMNIST).graph
    mg = build_multigraph(GAIA, FEMNIST, overlay, t=5)
    vec = tuple(mg.multiplicity[p] for p in overlay.pairs)
    plan = search.multiplicity_plan(GAIA, FEMNIST, overlay, vec)
    ref = timing.multigraph_timing_plan(GAIA, FEMNIST, t=5, overlay=overlay)
    np.testing.assert_array_equal(plan.strong, ref.strong)
    np.testing.assert_array_equal(plan.d0, ref.d0)
    np.testing.assert_array_equal(plan.cycle_times(300),
                                  ref.cycle_times(300))


def test_search_matches_or_beats_paper_design():
    res = search.search_design(GAIA, FEMNIST, rounds=400, max_iters=4)
    assert res.best_mean_ms <= res.paper_mean_ms
    # the density floor held: the searched design communicates at least
    # as densely as the hand-built one
    assert res.best_strong_frac >= res.paper_strong_frac - 1e-9
    assert all(1 <= m <= res.t_max for m in res.best_mults)
    assert res.evaluations > 0 and res.elapsed_s > 0


def test_search_unconstrained_degenerates_cheaper():
    """Dropping the density floor can only lower the optimum (larger
    feasible set) — and documents WHY the floor exists."""
    a = search.search_design(GAIA, FEMNIST, rounds=300, max_iters=3,
                             density_floor=True)
    b = search.search_design(GAIA, FEMNIST, rounds=300, max_iters=3,
                             density_floor=False)
    assert b.best_mean_ms <= a.best_mean_ms


def test_search_cli_smoke(capsys):
    rc = search.main(["--networks", "gaia", "--workloads", "femnist",
                      "--rounds", "300", "--max-iters", "2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "design search" in out and "gaia" in out
