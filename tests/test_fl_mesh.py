"""Mesh-sharded flat FL runtime (fl/mesh.py, DESIGN.md §16).

Tier-1 runs this file on however many devices the host exposes (1 in
the default run — the mesh degenerates to one shard but every table,
pad and collective still executes). The `fl-mesh` CI job re-runs the
SAME file with XLA_FLAGS=--xla_force_host_platform_device_count=8, so
the bit-exactness assertions also hold at 8 real shards; the slow tier
additionally drives tests/mp_scripts/mesh_check.py in a subprocess so
8-device coverage exists locally too.

Backend equivalence on random CSR graphs needs NO devices at all: the
gossip collectives run under `jax.vmap(..., axis_name=...)`, which
gives every shard its own named-axis instance in one process.
"""

import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.delay import FEMNIST
from repro.fl import dpasgd, gossip, lora
from repro.fl import mesh as flmesh
from repro.fl import runtime as rtmod
from repro.kernels.gossip_combine.ops import csr_sort
from repro.kernels.gossip_combine.ref import edge_aggregate_ref
from repro.launch.mesh import fl_mesh, silo_assignment
from repro.networks.zoo import get_network
from repro.optim import flat_sgd

D_MODEL = 8


def _toy_init(key):
    return {"w": jax.random.normal(key, (D_MODEL,)), "b": jnp.zeros((3,))}


def _toy_loss(p, batch):
    return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)


def _run_single(plan, key, batches_all, momentum=0.9):
    n = int(plan.diag.shape[1])
    opt = flat_sgd(0.05, momentum=momentum)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(_toy_init, key), n)
    state = rtmod.init_flat_state(_toy_init, opt, rt, key)
    cycle = rtmod.make_cycle_fn(rt, loss_fn=_toy_loss, opt=opt)
    r = batches_all.shape[0]
    state, losses = cycle(state, {"t": jnp.asarray(batches_all)},
                          jnp.asarray(rt.strong[:r]),
                          jnp.asarray(rt.coeffs[:r]),
                          jnp.asarray(rt.diag[:r]))
    return rt, state, np.asarray(losses)


def _run_mesh(plan, key, batches_all, momentum=0.9, backend="halo"):
    n = int(plan.diag.shape[1])
    opt = flat_sgd(0.05, momentum=momentum)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(_toy_init, key), n)
    mrt = flmesh.make_mesh_runtime(rt)  # every device the host exposes
    state = flmesh.init_mesh_state(_toy_init, opt, mrt, key)
    cycle = rtmod.make_cycle_fn(mrt, loss_fn=_toy_loss, opt=opt,
                                gossip=backend)
    r = batches_all.shape[0]
    state, losses = cycle(state, {"t": jnp.asarray(batches_all)},
                          jnp.asarray(rt.strong[:r]),
                          jnp.asarray(rt.coeffs[:r]),
                          jnp.asarray(rt.diag[:r]))
    return mrt, state, np.asarray(losses), cycle


# ---------------------------------------------------------------------------
# layout invariants (host-side, no devices involved)
# ---------------------------------------------------------------------------


def test_silo_assignment_geometry():
    a = silo_assignment(11, 4)
    assert (a.per_shard, a.rows_padded) == (3, 12)
    for s in range(11):
        p = a.shard_of(s)
        assert 0 <= p < 4 and p * a.per_shard + a.local_of(s) == s
    assert silo_assignment(8, 8).per_shard == 1
    assert silo_assignment(3, 8).rows_padded == 8


def _random_plan_arrays(n, rng, isolated=()):
    """Random directed CSR edge structure avoiding `isolated` nodes."""
    nodes = [i for i in range(n) if i not in isolated]
    pairs = set()
    while len(pairs) < max(1, 2 * len(nodes)):
        i, j = rng.choice(nodes, 2, replace=False)
        pairs.add((min(i, j), max(i, j)))
    src = np.array([e for i, j in sorted(pairs) for e in (i, j)], np.int64)
    dst = np.array([e for i, j in sorted(pairs) for e in (j, i)], np.int64)
    order, row_ptr = csr_sort(dst, n)
    return src[order].astype(np.int32), dst[order].astype(np.int32), row_ptr


@pytest.mark.parametrize("n,d,isolated", [(10, 2, ()), (11, 4, (0, 7)),
                                          (16, 8, (3,)), (5, 8, ())])
def test_block_layout_invariants(n, d, isolated):
    rng = np.random.default_rng(n * 100 + d)
    src, dst, _ = _random_plan_arrays(n, rng, isolated)
    per = -(-n // d)
    counts, edge_perm, dst_local, src_global = flmesh.block_layout(
        src_sorted=src, dst_sorted=dst, d=d, per=per)
    e2 = len(dst)
    real = edge_perm[edge_perm < e2]
    # every real edge appears exactly once, in sorted order
    np.testing.assert_array_equal(np.sort(real), np.arange(e2))
    np.testing.assert_array_equal(real, np.sort(real))
    assert counts.sum() == e2
    for p in range(d):
        c = int(counts[p])
        # real edges: local dst in range and consistent with global
        np.testing.assert_array_equal(
            dst_local[p, :c] + p * per,
            dst[int(edge_perm[p * dst_local.shape[1]]):][:c])
        assert (dst_local[p, :c] < per).all()
        # pad edges: dst == per => segment_sum drops them
        assert (dst_local[p, c:] == per).all()
        np.testing.assert_array_equal(src_global[p, :c],
                                      src[edge_perm[p * dst_local.shape[1]:
                                                    p * dst_local.shape[1]
                                                    + c]])


# ---------------------------------------------------------------------------
# gossip backend equivalence on random CSR graphs (vmap named axis —
# multi-shard semantics without multi-device hardware)
# ---------------------------------------------------------------------------


def _vmap_gather(w_pad, d, per, layout, halo, backend):
    """Run a csr gather backend with vmap providing the silo axis."""
    _, _, _, src_global = layout
    w_shards = w_pad.reshape(d, per, w_pad.shape[-1])
    if backend == "all_gather":
        fn = lambda w, s: gossip.csr_gather_all(w, s, "s")
        return jax.vmap(fn, axis_name="s")(w_shards,
                                           jnp.asarray(src_global))
    sends = tuple(jnp.asarray(t) for t in halo.send_idx)

    def fn(w, gath, *sends_p):
        return gossip.csr_gather_halo(w, sends_p, halo.perms, gath, "s")

    return jax.vmap(fn, axis_name="s")(w_shards,
                                       jnp.asarray(halo.gather_idx), *sends)


@pytest.mark.parametrize("n,d,isolated", [(12, 3, ()), (11, 4, (2, 9)),
                                          (9, 2, (0,))])
def test_csr_backends_match_flat_aggregate(n, d, isolated):
    """all_gather == halo == single-device edge_aggregate, with isolated
    nodes exercising empty CSR rows (S3)."""
    rng = np.random.default_rng(7 * n + d)
    src, dst, _ = _random_plan_arrays(n, rng, isolated)
    per = -(-n // d)
    npad, t = d * per, 6
    layout = flmesh.block_layout(dst_sorted=dst, src_sorted=src, d=d, per=per)
    counts, edge_perm, dst_local, src_global = layout
    halo = flmesh._build_halo(counts, src_global, d, per)

    w = np.asarray(rng.normal(size=(npad, t)), np.float32)
    coeffs = np.asarray(rng.uniform(0.1, 1.0, size=len(dst)), np.float32)
    diag = np.asarray(rng.uniform(0.1, 1.0, size=n), np.float32)

    # oracle: single-device flat aggregation over fresh buffers
    ref = edge_aggregate_ref(jnp.asarray(w[:n]), jnp.asarray(w[src]),
                             jnp.asarray(coeffs), jnp.asarray(dst),
                             jnp.asarray(diag))

    e_per = dst_local.shape[1]
    coeffs_p = np.concatenate([coeffs, [0.0]]).astype(np.float32)[
        np.minimum(edge_perm, len(dst))].reshape(d, e_per)
    diag_p = np.concatenate([diag, np.ones(npad - n, np.float32)])

    for backend in ("all_gather", "halo"):
        rows = _vmap_gather(jnp.asarray(w), d, per, layout, halo, backend)
        # gathered source rows must be exact for every REAL edge
        for p in range(d):
            c = int(counts[p])
            np.testing.assert_array_equal(np.asarray(rows)[p, :c],
                                          w[src_global[p, :c]])
        agg = jax.vmap(edge_aggregate_ref)(
            jnp.asarray(w.reshape(d, per, t)), rows,
            jnp.asarray(coeffs_p), jnp.asarray(dst_local),
            jnp.asarray(diag_p.reshape(d, per)))
        got = np.asarray(agg).reshape(npad, t)[:n]
        np.testing.assert_array_equal(got, np.asarray(ref))


def test_gossip_dense_matches_flat_aggregate():
    """The production all_gather consensus (gossip_dense) equals the
    flat runtime's edge_aggregate on the same consensus matrix."""
    n, t = 8, 5
    rng = np.random.default_rng(0)
    src, dst, _ = _random_plan_arrays(n, rng, isolated=(5,))
    coeffs = np.asarray(rng.uniform(0.1, 0.5, len(dst)), np.float32)
    diag = np.asarray(rng.uniform(0.3, 1.0, n), np.float32)
    a = np.zeros((n, n), np.float32)
    a[np.arange(n), np.arange(n)] = diag
    np.add.at(a, (dst, src), coeffs)
    w = np.asarray(rng.normal(size=(n, t)), np.float32)

    dense = jax.vmap(lambda wi: gossip.gossip_dense(wi, jnp.asarray(a), "s"),
                     axis_name="s")(jnp.asarray(w))
    ref = edge_aggregate_ref(jnp.asarray(w), jnp.asarray(w[src]),
                             jnp.asarray(coeffs), jnp.asarray(dst),
                             jnp.asarray(diag))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ref),
                               rtol=2e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# whole-cycle bit-exactness: sharded == single-device oracle
# ---------------------------------------------------------------------------


def _cycle_batches(plan, n, seed, u=1):
    r = plan.num_rounds_cycle
    rng = np.random.default_rng(seed)
    return np.asarray(rng.normal(size=(r, u, n, 1, D_MODEL)), np.float32)


@pytest.mark.parametrize("net_name", ["gaia", "amazon", "geant", "exodus",
                                      "ebone"])
def test_mesh_cycle_bitexact_paper_networks(net_name):
    """Params, edge buffers AND momentum bit-for-bit equal to the
    single-device oracle over a full multigraph cycle (the acceptance
    contract). Runs at whatever device count the process has."""
    net = get_network(net_name)
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    n = net.num_silos
    batches = _cycle_batches(plan, n, seed=net.num_silos)
    key = jax.random.PRNGKey(7)
    _, s1, l1 = _run_single(plan, key, batches)
    mrt, sm, lm, _ = _run_mesh(plan, key, batches)
    flat = flmesh.gather_flat_state(mrt, sm)
    np.testing.assert_array_equal(np.asarray(s1.w), np.asarray(flat.w))
    np.testing.assert_array_equal(np.asarray(s1.buffers),
                                  np.asarray(flat.buffers))
    np.testing.assert_array_equal(np.asarray(s1.opt_state["mu"]),
                                  np.asarray(flat.opt_state["mu"]))
    # loss scalars: reduce-to-scalar emitter may differ by ~1 ulp
    # between the two loop programs (DESIGN.md §16)
    np.testing.assert_allclose(l1, lm, rtol=5e-7, atol=0)


def test_all_gather_backend_bitexact():
    net = get_network("gaia")
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    batches = _cycle_batches(plan, net.num_silos, seed=1)
    key = jax.random.PRNGKey(3)
    _, s1, _ = _run_single(plan, key, batches)
    mrt, sm, _, _ = _run_mesh(plan, key, batches, backend="all_gather")
    flat = flmesh.gather_flat_state(mrt, sm)
    np.testing.assert_array_equal(np.asarray(s1.w), np.asarray(flat.w))
    np.testing.assert_array_equal(np.asarray(s1.buffers),
                                  np.asarray(flat.buffers))


def test_mesh_live_swap_traces_once():
    """Controller contract: a swapped schedule is just new arguments —
    the shard_map cycle never re-traces."""
    net = get_network("gaia")
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    batches = _cycle_batches(plan, net.num_silos, seed=2)
    key = jax.random.PRNGKey(5)
    mrt, state, _, cycle = _run_mesh(plan, key, batches)
    r = batches.shape[0]
    swapped = ~np.asarray(mrt.strong[:r])
    state, losses = cycle(state, {"t": jnp.asarray(batches)},
                          jnp.asarray(swapped),
                          jnp.asarray(mrt.coeffs[:r]),
                          jnp.asarray(mrt.diag[:r]))
    assert losses.shape == (r,)
    assert cycle.trace_count["count"] == 1, cycle.trace_count


def test_fl_mesh_errors():
    with pytest.raises(RuntimeError, match="devices"):
        fl_mesh(jax.device_count() + 1)
    net = get_network("gaia")
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    key = jax.random.PRNGKey(0)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(_toy_init, key), 11)
    opt = flat_sgd(0.05)
    with pytest.raises(ValueError, match="gossip"):
        rtmod.make_cycle_fn(rt, loss_fn=_toy_loss, opt=opt, gossip="halo")
    mrt = flmesh.make_mesh_runtime(rt, 1)
    with pytest.raises(ValueError, match="backend"):
        rtmod.make_cycle_fn(mrt, loss_fn=_toy_loss, opt=opt, gossip="bogus")
    with pytest.raises(ValueError, match="single-device"):
        rtmod.make_cycle_fn(mrt, loss_fn=_toy_loss, opt=opt,
                            aggregator="dense")


# ---------------------------------------------------------------------------
# LoRA deltas over a shared base (fl/lora.py)
# ---------------------------------------------------------------------------


def test_lora_init_is_identity():
    key = jax.random.PRNGKey(0)
    base = {"m": jax.random.normal(key, (16, 12)),
            "s": jax.random.normal(key, (3, 10, 8)),
            "b": jnp.ones((12,))}
    ad = lora.make_lora_adapter(base, rank=2)
    p0 = ad.apply(ad.init(jax.random.PRNGKey(1)))
    for k in base:
        np.testing.assert_array_equal(np.asarray(p0[k]), np.asarray(base[k]))


def test_lora_size_and_template():
    key = jax.random.PRNGKey(0)
    base = {"m": jax.random.normal(key, (64, 48)), "b": jnp.ones((48,)),
            "tiny": jnp.ones((2, 2))}
    tmpl = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                        base)
    t_lora = lora.lora_size(tmpl, 4)
    # (64+48)*4 low-rank + 48 dense bias + 4 dense tiny (low-rank would
    # be bigger than 2x2, so it stays dense)
    assert t_lora == (64 + 48) * 4 + 48 + 4
    ad = lora.make_lora_adapter(base, rank=4)
    flat = sum(int(np.prod(l.shape)) for l in
               jax.tree.leaves(jax.eval_shape(ad.init, key)))
    assert flat == t_lora
    assert t_lora < sum(int(np.prod(l.shape))
                        for l in jax.tree.leaves(base))


def test_lora_mesh_cycle_matches_single_device():
    """LoRA deltas ride the mesh runtime unchanged: T is just smaller."""
    net = get_network("gaia")
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    n = net.num_silos
    key = jax.random.PRNGKey(0)
    base = {"w1": jax.random.normal(key, (12, 8)), "b": jnp.zeros((8,))}
    ad = lora.make_lora_adapter(base, rank=2)

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w1"] + p["b"]) ** 2)

    opt = flat_sgd(0.05, momentum=0.9)
    rt = rtmod.make_flat_runtime(plan, jax.eval_shape(ad.init, key), n)
    r = plan.num_rounds_cycle
    rng = np.random.default_rng(0)
    batches = {"x": jnp.asarray(rng.normal(size=(r, 1, n, 2, 12)),
                                jnp.float32)}
    args = (batches, jnp.asarray(rt.strong), jnp.asarray(rt.coeffs),
            jnp.asarray(rt.diag))
    s0 = rtmod.init_flat_state(ad.init, opt, rt, key)
    c0 = rtmod.make_cycle_fn(rt, loss_fn=ad.wrap_loss(loss_fn), opt=opt)
    s0, l0 = c0(s0, *args)

    mrt = flmesh.make_mesh_runtime(rt)
    sm = flmesh.init_mesh_state(ad.init, opt, mrt, key)
    cm = rtmod.make_cycle_fn(mrt, loss_fn=ad.wrap_loss(loss_fn), opt=opt)
    sm, lm = cm(sm, *args)
    flat = flmesh.gather_flat_state(mrt, sm)
    np.testing.assert_array_equal(np.asarray(s0.w), np.asarray(flat.w))
    np.testing.assert_array_equal(np.asarray(s0.buffers),
                                  np.asarray(flat.buffers))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(lm),
                               rtol=5e-7, atol=0)
    # training actually moved the deltas
    assert float(np.abs(np.asarray(s0.w)).max()) > 0


def test_fl_mesh_roofline_validates_lora():
    """The memory model the tentpole rests on: full per-silo state for
    gemma3-27b cannot fit a shard device, the LoRA layout can."""
    from repro.launch.roofline import fl_mesh_report
    r = fl_mesh_report("gemma3-27b", num_shards=8, rank=8)
    assert not r["full"]["fits"]
    assert r["lora"]["fits"]
    assert r["t_lora"] < r["t_full"] / 100
    coll = r["lora"]["collective_bytes_per_round"]
    assert coll["halo"] <= coll["all_gather"]
    small = fl_mesh_report("mamba2-370m", num_shards=8, rank=8)
    assert small["lora"]["fits"]


# ---------------------------------------------------------------------------
# CI smoke (fl-mesh job): femnist, one eval period, mesh vs oracle
# ---------------------------------------------------------------------------


def test_femnist_mesh_smoke():
    """run_fl on gaia/FEMNIST for one short horizon: the mesh path must
    reproduce the oracle's accuracies exactly and losses to ~1 ulp.
    This is the <90 s fl-mesh CI smoke."""
    from repro.fl.trainer import FLConfig, run_fl
    base = dict(dataset="femnist", network="gaia", rounds=2, eval_every=2,
                samples_per_silo=16, batch_size=4, momentum=0.9, seed=3)
    r1 = run_fl(FLConfig(**base))
    r2 = run_fl(FLConfig(**base, mesh="auto"))
    np.testing.assert_allclose(np.asarray(r1.round_losses),
                               np.asarray(r2.round_losses),
                               rtol=5e-7, atol=0)
    np.testing.assert_array_equal(np.asarray(r1.eval_accs),
                                  np.asarray(r2.eval_accs))


def test_wan_generated_network():
    net = get_network("wan64")
    assert net.num_silos == 64 and net.name == "wan64"
    assert net.latency_ms.shape == (64, 64)
    np.testing.assert_array_equal(net.latency_ms,
                                  get_network("wan64").latency_ms)
    plan, _, _ = dpasgd.multigraph_plan(net, FEMNIST, t=5)
    assert plan.num_rounds_cycle > 0


# ---------------------------------------------------------------------------
# slow tier: 8-device subprocess + controller/trainer integration
# ---------------------------------------------------------------------------


def _run_script(script, timeout=1500, extra_env=()):
    src = pathlib.Path(__file__).parent.parent / "src"
    # JAX_PLATFORMS=cpu: don't let the child probe accelerator plugins
    # the pytest process may already hold (libtpu serializes on a
    # lockfile; the child would sleep in TPU discovery forever).
    env = {"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", **dict(extra_env)}
    return subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_mesh_runtime_8_devices():
    script = (pathlib.Path(__file__).parent / "mp_scripts"
              / "mesh_check.py")
    r = _run_script(script)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("gaia-halo-bitexact-ok", "gaia-all_gather-bitexact-ok",
                   "amazon-halo-bitexact-ok",
                   "amazon-all_gather-bitexact-ok", "swap-trace-once-ok"):
        assert marker in r.stdout, r.stdout


@pytest.mark.slow
def test_trainer_mesh_parity_longer():
    from repro.fl.trainer import FLConfig, run_fl
    base = dict(dataset="femnist", network="amazon", rounds=8, eval_every=4,
                samples_per_silo=16, batch_size=4, momentum=0.9, seed=0)
    r1 = run_fl(FLConfig(**base))
    r2 = run_fl(FLConfig(**base, mesh="auto", gossip="all_gather"))
    np.testing.assert_allclose(np.asarray(r1.round_losses),
                               np.asarray(r2.round_losses),
                               rtol=5e-7, atol=0)
    np.testing.assert_array_equal(np.asarray(r1.eval_accs),
                                  np.asarray(r2.eval_accs))


@pytest.mark.slow
def test_controller_mesh_nominal_parity():
    from repro.design.controller import ControllerConfig, ControllerHarness
    kw = dict(network="gaia", rounds=24, replan_every=12,
              samples_per_silo=16, batch_size=4, seed=3)
    ad = ControllerHarness(ControllerConfig(**kw, mesh="auto")).run(
        "nominal", adaptive=True)
    st = ControllerHarness(ControllerConfig(**kw)).run(
        "nominal", adaptive=True)
    np.testing.assert_allclose(np.asarray(ad.losses), np.asarray(st.losses),
                               rtol=5e-7, atol=0)
    assert ad.swap_rounds == ()


@pytest.mark.slow
def test_fl_llm_finetune_example_runs():
    """S6: the example actually runs, wired to the sharded runtime."""
    root = pathlib.Path(__file__).parent.parent
    src = root / "src"
    r = subprocess.run(
        [sys.executable, str(root / "examples" / "fl_llm_finetune.py"),
         "--rounds", "4", "--silos", "4"],
        capture_output=True, text=True, timeout=1500,
        env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "wall-clock speedup vs RING" in r.stdout, r.stdout
